"""Cross-tenant property-test pass (hypothesis).

Five guarantees over random fleets (mixed shuffle/keyed DAGs, skewed
priorities, heterogeneous machine mixes):

1. **Solo equivalence** — N == 1 is bit-identical to the stock
   ``schedule() + refine()`` pipeline.
2. **Permutation invariance** — tenant submission order changes the
   report order and nothing else (rates and placements bit-identical;
   every cross-tenant reduction sums in canonical name order).
3. **Capacity invariant** — total linear load never exceeds capacity
   (validated after every water-filling round, not just at the end).
4. **Solo-no-regression** — every tenant gets at least its fair-slice
   solo rate (the warm-start guarantee).
5. **Determinism** — repeated runs are bit-identical.

Deterministic twins of these live in ``test_multitenant.py`` so the fast
tier covers the package when hypothesis is absent.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import ScheduleState, refine, schedule
from repro.multitenant import (
    MultiTenantState,
    TenantSet,
    fair_shares,
    schedule_tenants,
)

from multitenant_strategies import random_tenant_fleet, roomy_cluster

SETTINGS = settings(
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)

FAST = dict(warm_refine_rounds=8, structure_attempts=1, refine_moves=1)


@SETTINGS
@given(fleet=random_tenant_fleet(min_tenants=1, max_tenants=1), data=st.data())
def test_solo_bit_identical(fleet, data):
    cluster = data.draw(roomy_cluster())
    tenant = fleet[0]
    ms = schedule_tenants(fleet, cluster)
    sched = schedule(tenant.utg, cluster, r0=1.0, rate_epsilon=0.5)
    ref = refine(sched.etg, cluster, skew=tenant.skew)
    alloc = ms.allocations[0]
    assert alloc.rate == ref.rate
    assert alloc.etg.task_machine().tolist() == ref.etg.task_machine().tolist()


@SETTINGS
@given(fleet=random_tenant_fleet(min_tenants=2, max_tenants=5), data=st.data())
def test_permutation_invariance(fleet, data):
    cluster = data.draw(roomy_cluster())
    perm = data.draw(st.permutations(list(range(len(fleet)))))
    a = schedule_tenants(fleet, cluster, **FAST)
    b = schedule_tenants(TenantSet([fleet[i] for i in perm]), cluster, **FAST)
    for t in fleet:
        x, y = a.allocation(t.name), b.allocation(t.name)
        assert x.rate == y.rate, t.name
        assert (
            x.etg.task_machine().tolist() == y.etg.task_machine().tolist()
        ), t.name


@SETTINGS
@given(fleet=random_tenant_fleet(min_tenants=2, max_tenants=6), data=st.data())
def test_capacity_invariant_every_round(fleet, data):
    cluster = data.draw(roomy_cluster())
    ms = schedule_tenants(fleet, cluster, validate=True, **FAST)
    states = [
        ScheduleState.from_etg(a.etg, cluster, skew=t.skew)
        for a, t in zip(ms.allocations, fleet)
    ]
    mt = MultiTenantState(fleet, cluster, states, rates=ms.rates)
    assert mt.feasible(slack=1e-9)
    assert np.all(ms.rates >= 0.0)


@SETTINGS
@given(fleet=random_tenant_fleet(min_tenants=2, max_tenants=5), data=st.data())
def test_solo_no_regression_vs_fair_slice(fleet, data):
    cluster = data.draw(roomy_cluster())
    ms = schedule_tenants(fleet, cluster, **FAST)
    shares = fair_shares(fleet)
    for i, tenant in enumerate(fleet):
        sliced = cluster.with_capacity(cluster.capacity * shares[i])
        solo = schedule(tenant.utg, sliced, r0=1.0, rate_epsilon=0.5)
        ref = refine(
            solo.etg,
            sliced,
            max_rounds=FAST["warm_refine_rounds"],
            skew=tenant.skew,
        )
        st = ScheduleState.from_etg(ref.etg, cluster, skew=tenant.skew)
        if not np.all(
            st.met_load + ref.rate * st.var_load <= sliced.capacity + 1e-9
        ):
            continue  # thin slice: baseline is 0, trivially satisfied
        assert ms.allocation(tenant.name).rate >= ref.rate * (1.0 - 1e-6), (
            tenant.name
        )


@SETTINGS
@given(fleet=random_tenant_fleet(min_tenants=2, max_tenants=5), data=st.data())
def test_determinism(fleet, data):
    cluster = data.draw(roomy_cluster())
    a = schedule_tenants(fleet, cluster, **FAST)
    b = schedule_tenants(fleet, cluster, **FAST)
    assert a.rates.tolist() == b.rates.tolist()
    assert a.rounds == b.rounds
    assert a.candidates_evaluated == b.candidates_evaluated
    for x, y in zip(a.allocations, b.allocations):
        assert x.etg.task_machine().tolist() == y.etg.task_machine().tolist()
