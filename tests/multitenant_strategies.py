"""Shared hypothesis strategies for the multi-tenant property suite.

Imported by ``test_multitenant_properties.py`` behind
``pytest.importorskip("hypothesis")`` (the dev image may not ship
hypothesis; CI installs it), so this module may import it at the top
level. Reuses the single-tenant DAG/cluster strategies from
``sched_strategies`` and wraps them into tenant fleets.
"""

import numpy as np
from hypothesis import strategies as st

from repro.core import paper_cluster
from repro.multitenant import Tenant, TenantSet

from sched_strategies import PROFILE, random_dag, random_keyed_dag


@st.composite
def random_tenant(draw, index: int, allow_skew: bool = True):
    """One tenant: a random (possibly keyed) DAG with a drawn contract.

    Priorities are drawn from a skewed palette (most tenants at 1, a few
    at 2x/4x) so weighted fairness actually differentiates; target rates
    span an order of magnitude so levels are not trivially comparable.
    """
    if allow_skew and draw(st.booleans()) and draw(st.booleans()):
        utg = draw(random_keyed_dag(max_components=5, max_keys=24))
    else:
        utg = draw(random_dag(max_components=5))
    return Tenant(
        name=f"t{index:03d}",
        utg=utg,
        target_rate=draw(st.floats(2.0, 40.0)),
        priority=draw(st.sampled_from([1.0, 1.0, 1.0, 2.0, 4.0])),
    )


@st.composite
def random_tenant_fleet(draw, min_tenants: int = 1, max_tenants: int = 6):
    """A fleet of 1..N tenants with unique names in drawn order."""
    n = draw(st.integers(min_tenants, max_tenants))
    tenants = [draw(random_tenant(i)) for i in range(n)]
    # Shuffle submission order — canonical (name) order must not depend
    # on it, which is exactly what the permutation property checks.
    perm = draw(st.permutations(list(range(n))))
    return TenantSet([tenants[i] for i in perm])


@st.composite
def roomy_cluster(draw, max_per_type: int = 2, floor: float = 150.0):
    """A shared cluster with enough per-machine capacity that every
    tenant's fair slice can host at least a minimal placement (MET is
    lumpy: below ~``N * met`` points per machine the fair-slice warm
    start legitimately defers tenants to rate 0, which is covered by the
    dedicated thin-slice test rather than drawn at random here)."""
    counts = tuple(draw(st.integers(0, max_per_type)) for _ in range(3))
    if sum(counts) == 0:
        counts = (1, 1, 1)
    cluster = paper_cluster(counts, PROFILE)
    scale = draw(st.floats(1.5, 4.0))
    return cluster.with_capacity(
        np.maximum(cluster.capacity * scale, floor)
    )
