"""Exact rational R* boundary arbitration (regression for the old 1e-9
re-check band).

The float closed form computes R* = min_w (cap_w - met_w) / var_w in
binary64; rates within one part in 1e9 of that quotient used to be
re-checked against a heuristic tolerance. The exact path instead treats
the cached float coefficients as rationals, so the feasibility boundary
is a hard number: ``rate`` is stable iff ``Fraction(rate) <= R*_exact``,
bit-for-bit, with no band.
"""

from fractions import Fraction

import numpy as np
import pytest

from repro.core import (
    ScheduleState,
    diamond_topology,
    linear_topology,
    paper_cluster,
    schedule,
    star_topology,
)

TOPOS = {
    "linear": linear_topology,
    "diamond": diamond_topology,
    "star": star_topology,
}


def _state(topo_name, counts=(1, 1, 1)):
    cluster = paper_cluster(counts)
    etg = schedule(TOPOS[topo_name](), cluster, r0=1.0, rate_epsilon=0.5).etg
    return ScheduleState.from_etg(etg, cluster)


@pytest.mark.parametrize("topo", sorted(TOPOS))
def test_exact_rstar_brackets_float_rstar(topo):
    """The float R* sits within one ulp-scale step of the exact rational
    boundary: float(R*_exact) rounds to the float R* (same closed form,
    same coefficients)."""
    st = _state(topo)
    r_float = st.max_stable_rate()
    r_exact = st.max_stable_rate_exact()
    assert r_exact is not None and r_exact > 0
    assert float(r_exact) == pytest.approx(r_float, rel=1e-15)


@pytest.mark.parametrize("topo", sorted(TOPOS))
def test_boundary_is_sharp(topo):
    """Feasibility flips exactly at the rational boundary: the largest
    float <= R*_exact is feasible, the smallest float > R*_exact is not —
    no band, no tolerance."""
    st = _state(topo)
    r_exact = st.max_stable_rate_exact()
    r = float(r_exact)
    # float(r_exact) may round up or down; pick the two floats that
    # straddle the rational boundary.
    lo = r if Fraction(r) <= r_exact else np.nextafter(r, 0.0)
    hi = np.nextafter(lo, np.inf)
    assert Fraction(float(lo)) <= r_exact < Fraction(float(hi))
    assert st.feasible_linear_exact(float(lo))
    assert not st.feasible_linear_exact(float(hi))


@pytest.mark.parametrize("topo", sorted(TOPOS))
def test_exact_agrees_with_fraction_comparison(topo):
    """feasible_linear_exact(rate) == (Fraction(rate) <= R*_exact) for a
    sweep of rates around the boundary."""
    st = _state(topo)
    r_exact = st.max_stable_rate_exact()
    r = float(r_exact)
    probes = [
        0.0,
        0.5 * r,
        np.nextafter(r, 0.0),
        r,
        np.nextafter(r, np.inf),
        1.5 * r,
    ]
    for rate in probes:
        assert st.feasible_linear_exact(float(rate)) == (
            Fraction(float(rate)) <= r_exact
        )


def test_first_over_machine_identifies_binding_machine():
    """Just past the boundary, the first over machine is the argmin of the
    float head/var limits (the binding machine of the closed form)."""
    st = _state("diamond", counts=(2, 2, 2))
    r_exact = st.max_stable_rate_exact()
    over = np.nextafter(float(r_exact), np.inf)
    if Fraction(float(over)) <= r_exact:  # float(r_exact) rounded down
        over = np.nextafter(over, np.inf)
    w = st.first_over_machine_exact(float(over))
    assert w is not None
    head = st.cluster.capacity - st.met_load
    with np.errstate(divide="ignore"):
        limits = np.where(
            st.var_load > 0.0, head / np.maximum(st.var_load, 1e-300), np.inf
        )
    assert limits[w] == limits.min()
    assert st.first_over_machine_exact(0.0) is None


def test_met_only_infeasibility_is_negative():
    """A placement whose fixed MET alone exceeds a machine's capacity
    reports a negative exact R* (and rate 0.0 from the float path)."""
    cluster = paper_cluster((1, 1, 1))
    etg = schedule(linear_topology(), cluster, r0=1.0, rate_epsilon=0.5).etg
    tiny = cluster.with_capacity(np.full(cluster.n_machines, 0.5))
    st = ScheduleState.from_etg(etg, tiny)
    r_exact = st.max_stable_rate_exact()
    assert r_exact is not None and r_exact < 0
    assert not st.feasible_linear_exact(0.0)
    assert st.max_stable_rate() == 0.0


def test_schedule_pipeline_unchanged_by_exact_arbiter():
    """End-to-end schedule() still lands on rates the exact model calls
    feasible — the arbiter only sharpens the band, never admits an
    infeasible rate."""
    for topo in TOPOS.values():
        cluster = paper_cluster((2, 2, 2))
        sched = schedule(topo(), cluster, r0=1.0, rate_epsilon=0.5)
        st = ScheduleState.from_etg(sched.etg, cluster)
        assert st.feasible_linear_exact(sched.rate)
