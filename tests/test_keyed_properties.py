"""Hypothesis property suite for fields grouping with skewed keys (ISSUE 5).

Randomized sweep over keyed graphs (mixed shuffle/fields edges, key
cardinality down to 1, skew exponent 0..2.5):

* shuffle grouping (no fields edges) flows through the keyed-aware code
  paths bit-identically to the even split;
* keyed randomness draws from an independent stream (rate/capacity arrays
  unchanged by compiling against a topology);
* realizations are seed-deterministic and their hash→instance shares are
  a partition of the stream;
* the skew-aware closed form never beats the even split, approximates it
  for (near-)uniform keys, and agrees with the brute-force per-instance
  feasibility search of tests/test_keyed_golden.py.
"""

import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (
    SkewModel,
    keyed_rolling_count_topology,
    max_stable_rate,
    paper_cluster,
    rolling_count_topology,
    schedule,
)
from repro.core.schedule_state import ScheduleState
from repro.runtime_stream import TraceSpec

from sched_strategies import random_dag, random_keyed_dag
from test_keyed_golden import _compile_keyed, _skew_model, brute_force_rstar

SETTINGS = settings(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


# ------------------------------------------------------- shuffle identity


@SETTINGS
@given(utg=random_dag(), seed=st.integers(0, 2**31 - 1))
def test_shuffle_scores_bit_identical_through_skew_paths(utg, seed):
    """An all-shuffle topology scored through the skew machinery (empty
    model, keyed-aware state engine) must reproduce the even-split floats
    bit-for-bit — the shuffle-grouping regression gate."""
    cluster = paper_cluster((1, 1, 1))
    etg = schedule(utg, cluster, r0=1.0, rate_epsilon=0.5).etg
    skew = SkewModel(utg, {})
    r_even, t_even = max_stable_rate(etg, cluster)
    r_skew, t_skew = max_stable_rate(etg, cluster, skew=skew)
    assert r_skew == r_even and t_skew == t_even
    state_even = ScheduleState.from_etg(etg, cluster)
    state_skew = ScheduleState.from_etg(etg, cluster, skew=skew)
    tm = state_even.task_machine()[None, :]
    assert (
        state_skew.score_task_machine_batch(tm)[1].tolist()
        == state_even.score_task_machine_batch(tm)[1].tolist()
    )
    np.testing.assert_array_equal(state_skew.var_load, state_even.var_load)


@SETTINGS
@given(utg=random_keyed_dag(), seed=st.integers(0, 2**31 - 1))
def test_compile_rates_unchanged_by_keyed_stream(utg, seed):
    """Keyed randomness draws from an independent child stream: compiling
    against the topology leaves rate/capacity arrays bit-identical."""
    cluster = paper_cluster((1, 1, 1))
    from repro.runtime_stream import rate_burst, rate_noise

    spec = TraceSpec(
        name="mix",
        n_windows=30,
        base_rate=2.0,
        events=(rate_burst(2.0, every=10, jitter=2), rate_noise(0.05)),
    )
    a = spec.compile(cluster, seed=seed)
    b = spec.compile(cluster, seed=seed, utg=utg)
    assert np.array_equal(a.rates, b.rates)
    assert np.array_equal(a.capacity, b.capacity)
    assert len(b.keyed) == len(utg.groupings)


# ------------------------------------------------------------ realizations


@SETTINGS
@given(
    utg=random_keyed_dag(min_fields_edges=1),
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 7),
)
def test_key_shares_partition_the_stream(utg, seed, n):
    """Every realization's shares are a non-negative partition of the edge
    stream at any instance count, and re-compiling with the same seed
    reproduces them bit-identically."""
    cluster = paper_cluster((1, 1, 1))
    tr = _compile_keyed(utg, cluster, seed)
    tr2 = _compile_keyed(utg, cluster, seed)
    assert tr.keyed and len(tr.keyed) == len(utg.groupings)
    for kt, kt2 in zip(tr.keyed, tr2.keyed):
        real, real2 = kt.realization_at(0), kt2.realization_at(0)
        assert np.array_equal(real.weights, real2.weights)
        assert np.array_equal(real.hashes, real2.hashes)
        s = real.shares(n)
        assert s.shape == (n,)
        assert np.all(s >= 0.0)
        assert abs(s.sum() - 1.0) < 1e-12
    skew = _skew_model(utg, cluster, seed)
    for c in skew.keyed_components:
        frac = skew.instance_fractions(c, n)
        assert np.all(frac >= 0.0)
        assert abs(frac.sum() - 1.0) < 1e-9


@SETTINGS
@given(utg=random_keyed_dag(min_fields_edges=1), seed=st.integers(0, 2**31 - 1))
def test_skew_irrelevant_on_single_machine(utg, seed):
    """On a 1-machine cluster the split within a component cannot matter:
    the machine sees the whole CIR either way, so the skew-aware and
    even-split bounds agree (to summation rounding). Note the *ordering*
    between them is NOT an invariant on real clusters — a lucky
    realization can put less load on the binding machine than the even
    split does — so agreement here is the sound version of 'skew only
    changes where load lands, never how much'."""
    cluster = paper_cluster((1, 0, 0))
    etg = schedule(utg, cluster, r0=1.0, rate_epsilon=0.5).etg
    skew = _skew_model(utg, cluster, seed)
    r_even, t_even = max_stable_rate(etg, cluster)
    r_skew, t_skew = max_stable_rate(etg, cluster, skew=skew)
    assert r_skew == pytest.approx(r_even, rel=1e-9, abs=1e-12)
    assert t_skew == pytest.approx(t_even, rel=1e-9, abs=1e-12)


@SETTINGS
@given(seed=st.integers(0, 2**31 - 1))
def test_uniform_keys_approximate_shuffle(seed):
    """Fields grouping with uniform keys and high cardinality ≈ shuffle:
    hash collisions leave only O(sqrt(N/K)) imbalance — in either
    direction (a lucky draw can under-load the binding machine)."""
    cluster = paper_cluster((1, 1, 1))
    utg = keyed_rolling_count_topology(n_keys=4096, zipf_s=0.0)
    etg = schedule(rolling_count_topology(), cluster, r0=1.0, rate_epsilon=0.5).etg
    etg_keyed = schedule(utg, cluster, r0=1.0, rate_epsilon=0.5).etg
    assert etg_keyed.task_machine().tolist() == etg.task_machine().tolist()
    skew = _skew_model(utg, cluster, seed)
    r_even, _ = max_stable_rate(etg, cluster)
    r_skew, _ = max_stable_rate(etg_keyed, cluster, skew=skew)
    assert 0.85 * r_even <= r_skew <= 1.15 * r_even


@SETTINGS
@given(utg=random_keyed_dag(min_fields_edges=1), seed=st.integers(0, 2**31 - 1))
def test_skew_bound_matches_bruteforce_random(utg, seed):
    """The closed-form skew bound equals an independent brute-force
    per-instance feasibility bisection on random keyed graphs."""
    cluster = paper_cluster((1, 1, 1))
    etg = schedule(utg, cluster, r0=1.0, rate_epsilon=0.5).etg
    reals = _compile_keyed(utg, cluster, seed).realizations_at(0)
    skew = SkewModel(utg, {e: r.shares for e, r in reals.items()})
    r_even, _ = max_stable_rate(etg, cluster)
    r_skew, _ = max_stable_rate(etg, cluster, skew=skew)
    r_bf = brute_force_rstar(etg, cluster, reals, hi=2.0 * max(r_even, 1.0))
    assert r_skew == pytest.approx(r_bf, rel=1e-6, abs=1e-9)
