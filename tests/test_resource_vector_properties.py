"""Property suite for the resource-vector & network-aware objective (ISSUE 10).

Four families, each a helper shared between a deterministic seeded sweep
(runs everywhere) and a hypothesis section (CI dev image):

* **neutral bit-identity** — a cluster with an all-zero distance matrix and
  infinite memory capacities exercises every resource code path yet must
  reproduce the scalar-CPU engines bit-for-bit;
* **memory hard mask** — engines never *return* an over-memory placement
  with a positive rate;
* **distance monotonicity** — R* of a fixed placement is non-increasing in
  any distance entry (cut traffic only ever adds CPU load);
* **backend parity** — NumPy vs XLA contraction vs Pallas-interpret agree
  to 1e-12 with identical feasibility masks and argmax across the shared /
  per-row / skew scoring regimes on resource clusters.
"""

import numpy as np
import pytest

from repro.core import (
    Cluster,
    UserGraph,
    max_stable_rate,
    max_stable_rate_batch,
    paper_cluster,
    rack_distance_matrix,
    refine,
    schedule,
)
from repro.core import cost_model
from repro.core.schedule_state import ScheduleState

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    from sched_strategies import (
        PROFILE,
        random_cluster,
        random_dag,
        random_resource_cluster,
        resource_attachment,
    )

    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

MEM = np.array([1.0, 2.0, 3.0, 4.0])


# ------------------------------------------------------------ check helpers


def _neutral_twin(cluster):
    """Zero-distance / infinite-memory view: resources active, never bind."""
    m = cluster.n_machines
    return Cluster(
        machine_types=cluster.machine_types,
        capacity=cluster.capacity,
        profile=cluster.profile.with_mem(MEM[: cluster.profile.n_task_types]),
        mem_capacity=np.full(m, np.inf),
        distance=np.zeros((m, m)),
        net_penalty=0.9,
    )


def _check_neutral_bit_identity(utg, cluster, seed=0):
    neutral = _neutral_twin(cluster)
    assert neutral.has_resources
    s0 = schedule(utg, cluster, r0=1.0, rate_epsilon=1.0)
    s1 = schedule(utg, neutral, r0=1.0, rate_epsilon=1.0)
    assert s0.rate == s1.rate
    assert np.array_equal(s0.etg.task_machine(), s1.etg.task_machine())
    r0 = refine(s0.etg, cluster, backend="numpy", max_rounds=2)
    r1 = refine(s1.etg, neutral, backend="numpy", max_rounds=2)
    assert float(r0.throughput) == float(r1.throughput)
    assert np.array_equal(r0.etg.task_machine(), r1.etg.task_machine())
    # Batched scoring of random rows is bitwise identical too.
    rng = np.random.default_rng(seed)
    T = int(s0.etg.total_tasks)
    tm = rng.integers(0, cluster.n_machines, size=(16, T))
    base = ScheduleState.from_etg(s0.etg, cluster)
    twin = ScheduleState.from_etg(s0.etg, neutral)
    for a, b in zip(
        base.score_task_machine_batch(tm, backend="numpy"),
        twin.score_task_machine_batch(tm, backend="numpy"),
    ):
        assert np.array_equal(a, b)


def _mem_load(etg, cluster):
    mem_c = cluster.profile.mem[etg.utg.component_types]
    load = np.zeros(cluster.n_machines)
    np.add.at(load, etg.task_machine(), mem_c[etg.task_component()])
    return load


def _check_memory_feasibility(utg, cluster):
    assert cluster.has_memory
    sched = schedule(utg, cluster, r0=1.0, rate_epsilon=1.0)
    if sched.rate > 0.0:
        assert np.all(_mem_load(sched.etg, cluster) <= cluster.mem_capacity)
    res = refine(sched.etg, cluster, backend="numpy", max_rounds=2)
    if float(res.throughput) > 0.0:
        assert np.all(_mem_load(res.etg, cluster) <= cluster.mem_capacity)


def _check_distance_monotone(utg, cluster, i, j, delta):
    assert cluster.has_network
    etg = schedule(utg, cluster, r0=1.0, rate_epsilon=1.0).etg
    before, _ = max_stable_rate(etg, cluster)
    bumped = cluster.distance.copy()
    bumped[i, j] += delta
    bumped[j, i] += delta
    after, _ = max_stable_rate(etg, cluster.with_resources(distance=bumped))
    assert after <= before


def _assert_parity(got, ref):
    r_ref, t_ref = ref
    r_got, t_got = got
    np.testing.assert_allclose(r_got, r_ref, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(t_got, t_ref, rtol=1e-12, atol=1e-12)
    assert np.array_equal(r_got == 0.0, r_ref == 0.0)
    if r_ref.size:
        assert int(np.argmax(t_got)) == int(np.argmax(t_ref))


def _check_backend_parity(utg, cluster, seed=0, per_row=False):
    """NumPy vs XLA vs Pallas-interpret on resource clusters."""
    pytest.importorskip("jax")
    from repro.kernels.sched_scoring.ops import closed_form_rates_sched

    etg = schedule(utg, cluster, r0=1.0, rate_epsilon=1.0).etg
    state = ScheduleState.from_etg(etg, cluster)
    rng = np.random.default_rng(seed)
    T = int(etg.total_tasks)
    tm = rng.integers(0, cluster.n_machines, size=(8, T))
    if per_row:
        n_inst = np.tile(etg.n_instances, (tm.shape[0], 1))
        ref = state.score_task_machine_batch(
            tm, n_instances=n_inst, backend="numpy"
        )
        got = state.score_task_machine_batch(
            tm, n_instances=n_inst, backend="jax"
        )
        _assert_parity(got, ref)
        return
    ref = state.score_task_machine_batch(tm, backend="numpy")
    _assert_parity(state.score_task_machine_batch(tm, backend="jax"), ref)
    # Pallas segmented-reduce kernel, interpret mode (CPU-testable), fed
    # the same resource operands the host paths compute.
    comp = etg.task_component()
    unit_ir = cost_model.instance_rates(etg, 1.0)
    net_var, mem, mem_cap = cost_model.resource_operands(
        cluster, tm, comp, unit_ir, utg.alpha,
        cost_model.component_rates(utg, 1.0), utg.edges, utg.component_types,
    )
    got = closed_form_rates_sched(
        tm, comp, unit_ir, state.e_cm, state.met_cm, cluster.capacity,
        impl="interpret",
        net_var=net_var, mem=mem, mem_capacity=mem_cap,
    )
    _assert_parity(got, ref)


def _check_skew_parity(seed=0):
    """Skew regime: keyed rows score the resource objective identically on
    every backend (the kernels are skew-agnostic — only unit rates move)."""
    pytest.importorskip("jax")
    from repro.core import keyed_rolling_count_topology
    from repro.runtime_stream import StreamExecutor, TraceSpec

    cluster = paper_cluster((1, 1, 1)).with_resources(
        distance=rack_distance_matrix(np.array([0, 0, 1])), net_penalty=0.3
    )
    utg = keyed_rolling_count_topology(n_keys=12, zipf_s=1.2)
    etg = schedule(utg, cluster, r0=1.0, rate_epsilon=0.5).etg
    probe = StreamExecutor(
        etg, cluster, TraceSpec(name="probe", n_windows=2, base_rate=1.0),
        seed=seed + 3,
    )
    skew = probe.skew_model_at(0)
    assert skew is not None
    rng = np.random.default_rng(seed)
    T = int(etg.total_tasks)
    tm = rng.integers(0, cluster.n_machines, size=(12, T))
    ref = max_stable_rate_batch(etg, cluster, tm, backend="numpy", skew=skew)
    got = max_stable_rate_batch(etg, cluster, tm, backend="jax", skew=skew)
    _assert_parity(got, ref)


# ------------------------------------------------- deterministic seed sweep


def _pinned_utg(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 6))
    types = np.concatenate([[0], rng.integers(1, 4, size=n - 1)])
    edges = set()
    for j in range(1, n):
        edges.add((int(rng.integers(0, j)), j))
    alpha = np.concatenate([[1.0], rng.uniform(0.5, 3.0, size=n - 1)])
    return UserGraph(
        name=f"pin{seed}",
        component_types=types,
        edges=tuple(sorted(edges)),
        alpha=alpha,
    )


def _pinned_resource_cluster(seed, with_memory=True, with_network=True):
    rng = np.random.default_rng(seed + 100)
    counts = tuple(int(c) for c in rng.integers(0, 3, size=3))
    if sum(counts) == 0:
        counts = (1, 1, 1)
    profile = paper_cluster((1, 1, 1)).profile
    mem_capacity = None
    if with_memory:
        profile = profile.with_mem(MEM)
        m = sum(counts)
        mem_capacity = rng.uniform(float(MEM.max()), 4.0 * float(MEM.sum()), m)
    cluster = paper_cluster(counts, profile)
    distance = None
    pen = 1.0
    if with_network:
        racks = rng.integers(0, 3, size=cluster.n_machines)
        distance = rack_distance_matrix(racks, cross_rack=2.5)
        pen = float(rng.uniform(0.0, 0.5))
    return cluster.with_resources(
        mem_capacity=mem_capacity, distance=distance, net_penalty=pen
    )


@pytest.mark.parametrize("seed", range(4))
def test_neutral_bit_identity_seeded(seed):
    _check_neutral_bit_identity(_pinned_utg(seed), _pinned_resource_cluster(
        seed, with_memory=False, with_network=False
    ).without_network())


@pytest.mark.parametrize("seed", range(4))
def test_memory_feasibility_seeded(seed):
    _check_memory_feasibility(
        _pinned_utg(seed), _pinned_resource_cluster(seed, with_network=False)
    )


@pytest.mark.parametrize("seed", range(4))
def test_distance_monotone_seeded(seed):
    cluster = _pinned_resource_cluster(seed, with_memory=False)
    m = cluster.n_machines
    if m < 2:
        pytest.skip("needs two machines for an off-diagonal entry")
    rng = np.random.default_rng(seed + 7)
    i, j = rng.choice(m, size=2, replace=False)
    _check_distance_monotone(
        _pinned_utg(seed), cluster, int(i), int(j), float(rng.uniform(0.1, 3.0))
    )


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("per_row", [False, True])
def test_backend_parity_seeded(seed, per_row):
    _check_backend_parity(
        _pinned_utg(seed), _pinned_resource_cluster(seed), seed, per_row=per_row
    )


def test_backend_parity_skew_seeded():
    _check_skew_parity(seed=1)


# ------------------------------------------------------------ hypothesis

if HAS_HYPOTHESIS:

    @given(random_dag(), random_cluster())
    @settings(max_examples=15, deadline=None)
    def test_neutral_bit_identity(topo, cluster):
        _check_neutral_bit_identity(topo, cluster)

    @given(random_dag(), random_resource_cluster(with_memory=True))
    @settings(max_examples=15, deadline=None)
    def test_memory_feasible_or_zero(topo, cluster):
        _check_memory_feasibility(topo, cluster)

    @given(
        random_dag(),
        random_resource_cluster(with_network=True),
        st.data(),
    )
    @settings(max_examples=15, deadline=None)
    def test_rstar_monotone_in_distance(topo, cluster, data):
        m = cluster.n_machines
        if m < 2:
            return
        i = data.draw(st.integers(0, m - 1))
        j = data.draw(st.integers(0, m - 1).filter(lambda x: x != i))
        delta = data.draw(st.floats(0.01, 5.0))
        _check_distance_monotone(topo, cluster, i, j, delta)

    @given(
        random_dag(),
        random_resource_cluster(),
        st.integers(0, 2**16),
        st.booleans(),
    )
    @settings(max_examples=10, deadline=None)
    def test_backend_parity(topo, cluster, seed, per_row):
        _check_backend_parity(topo, cluster, seed, per_row=per_row)

    @given(st.integers(0, 2**8))
    @settings(max_examples=5, deadline=None)
    def test_backend_parity_skew(seed):
        _check_skew_parity(seed)
