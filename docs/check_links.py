"""Check internal markdown links in docs/ and README.md.

Verifies that every relative link target (``[text](path)`` and
``[text](path#anchor)``) resolves to an existing file. External
(http/https/mailto) links are skipped; plain-text/inline-code path
references in tables are not checked. Exits non-zero after collecting all
failures.

Usage: python docs/check_links.py  (from the repo root)
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

ROOT = Path(__file__).resolve().parent.parent


def check_file(md: Path) -> list[str]:
    errors = []
    for link in LINK_RE.findall(md.read_text()):
        if link.startswith(("http://", "https://", "mailto:")):
            continue
        target = link.split("#", 1)[0]
        if not target:
            continue  # pure anchor
        resolved = (md.parent / target).resolve()
        if not resolved.exists():
            errors.append(f"{md.relative_to(ROOT)}: broken link -> {link}")
    return errors


def main() -> int:
    files = sorted(ROOT.glob("docs/*.md")) + [
        ROOT / "README.md",
        ROOT / "DESIGN.md",  # links-only pointer into docs/ — must not rot
    ]
    errors = []
    for md in files:
        if md.exists():
            errors.extend(check_file(md))
    for err in errors:
        print(err)
    print(f"checked {len(files)} files: {'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
