"""Extract and execute the quickstart snippet from docs/api.md.

Keeps the documented quickstart honest: CI (and the tier-1 docs test) runs
exactly what the docs show. Requires PYTHONPATH=src.

Usage: PYTHONPATH=src python docs/run_quickstart.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path


def extract_snippet(md_path: Path) -> str:
    text = md_path.read_text()
    m = re.search(r"```python\n(.*?)```", text, flags=re.DOTALL)
    if not m:
        raise SystemExit(f"no ```python block found in {md_path}")
    return m.group(1)


def main() -> int:
    snippet = extract_snippet(Path(__file__).resolve().parent / "api.md")
    code = compile(snippet, "docs/api.md#quickstart", "exec")
    exec(code, {"__name__": "__docs_quickstart__"})
    print("quickstart snippet: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
